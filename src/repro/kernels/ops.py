"""Jitted public wrappers around the Pallas kernels.

Handle: arbitrary (non-tile-aligned) shapes via padding, >2-D payloads via
flattening, CPU fallback via interpret mode, and a pure-jnp escape hatch
(``backend='jnp'``) so the framework runs everywhere.  The collective layer
calls these; kernels never leak pallas details upward.
"""
from __future__ import annotations

import functools

import jax

from . import block_reduce as _br
from . import quantize as _qz
from . import ref as _ref


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad2d(x, rt, ct):
    r, c = x.shape
    return _qz.pad2d(x, rt, ct), (r, c)


def _to2d(x):
    """Flatten anything to 2-D (leading, rest)."""
    if x.ndim == 2:
        return x, x.shape
    if x.ndim < 2:
        return x.reshape(1, -1), x.shape
    return x.reshape(x.shape[0], -1), x.shape


@functools.partial(jax.jit, static_argnames=("op", "backend"))
def fused_block_reduce(a: jax.Array, b: jax.Array, *, op: str = "add",
                       backend: str = "pallas") -> jax.Array:
    """``a ⊕ b`` with VMEM tiling (any shape, any rank)."""
    if backend == "jnp":
        return _ref.block_reduce_ref(a, b, op=op)
    a2, orig_shape = _to2d(a)
    b2, _ = _to2d(b)
    rt, ct = _br.DEFAULT_ROW_TILE, _br.DEFAULT_COL_TILE
    rt, ct = min(rt, a2.shape[0]), min(ct, a2.shape[1])
    ap, (r, c) = _pad2d(a2, rt, ct)
    bp, _ = _pad2d(b2, rt, ct)
    out = _br.block_reduce(ap, bp, op=op, row_tile=rt, col_tile=ct,
                           interpret=_interpret_default())
    return out[:r, :c].reshape(orig_shape)


def quantize_blocks(x: jax.Array, *, group: int = _qz.DEFAULT_GROUP,
                    backend: str = "pallas"):
    """int8-quantize a payload; returns {'codes', 'scales'} pytree whose
    leaves ppermute independently (the compressed-round payload).  Ragged
    shapes are handled inside the kernel (pad-and-slice), so ``codes``
    has exactly the flattened input shape."""
    x2, orig_shape = _to2d(x)
    cols = x2.shape[1]
    g = min(group, cols)
    if backend == "jnp":
        codes, scales = _ref.quantize_ref(x2, group=g)
    else:
        codes, scales = _qz.quantize(x2, group=g, row_tile=1,
                                     interpret=_interpret_default())
    return {"codes": codes, "scales": scales,
            "meta": (orig_shape, cols, g)}


def dequantize_blocks(payload, *, backend: str = "pallas") -> jax.Array:
    """Inverse of quantize_blocks (unfused; for tests/serving)."""
    orig_shape, cols, g = payload["meta"]
    x = _ref.dequant_ref(payload["codes"], payload["scales"], group=g)
    return x.reshape(orig_shape)


def dequant_accumulate(acc: jax.Array, payload, *,
                       backend: str = "pallas") -> jax.Array:
    """Fused ``acc + dequant(payload)`` — the compressed-round ⊕."""
    orig_shape, cols, g = payload["meta"]
    acc2, _ = _to2d(acc)
    if backend == "jnp":
        out = _ref.dequant_add_ref(acc2, payload["codes"], payload["scales"],
                                   group=g)
    else:
        out = _qz.dequant_add(acc2, payload["codes"], payload["scales"],
                              group=g, row_tile=1,
                              interpret=_interpret_default())
    return out.reshape(orig_shape)


def make_compressors(group: int = _qz.DEFAULT_GROUP, backend: str = "pallas"):
    """(compress, decompress) pair for circulant_reduce_scatter's per-round
    hooks.  The collective ppermutes every array leaf of the compressed
    payload; static shape metadata must NOT ride along (it would be traced
    and/or ppermuted), so it is carried through a trace-time closure —
    compress and decompress are always called back-to-back within one
    round's trace, so a single-slot cell is sound."""
    meta_cell: dict[str, tuple] = {}

    def compress(x):
        payload = quantize_blocks(x, group=group, backend=backend)
        meta_cell["meta"] = payload.pop("meta")
        return payload

    def decompress(payload):
        payload = dict(payload)
        payload["meta"] = meta_cell["meta"]
        return dequantize_blocks(payload, backend=backend)

    return compress, decompress
