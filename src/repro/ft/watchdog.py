"""Straggler detection + mitigation policy.

At 1000+ nodes, slow hosts (thermal throttling, failing HBM, network
degradation) stretch every synchronous collective.  The watchdog keeps an
EWMA/EWVAR of step wall-times, flags steps beyond ``k`` sigma, and drives a
policy:

  observe -> {OK, SLOW, STRAGGLER}
  STRAGGLER streaks >= patience  ->  action callback (checkpoint-and-
  rebalance on real deployments; here: recorded + tested against synthetic
  traces).

A complementary knob it can pull on a live system: switch the grad-sync
schedule (Corollary 2) — e.g. from 'halving' to 'sqrt' — trading more,
smaller rounds for less per-round payload so a slow link hurts each round
less; the launcher re-jits with the new schedule at the next checkpoint
boundary (schedules are trace-time static).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable


@dataclass
class WatchdogConfig:
    alpha: float = 0.1          # EWMA smoothing
    sigma_slow: float = 2.0     # flag threshold
    sigma_straggler: float = 4.0
    patience: int = 3           # straggler streak before action
    warmup: int = 5             # steps ignored (compile etc.)


@dataclass
class Watchdog:
    cfg: WatchdogConfig = field(default_factory=WatchdogConfig)
    mean: float = 0.0
    var: float = 0.0
    count: int = 0
    streak: int = 0
    events: list = field(default_factory=list)
    on_straggler: Callable[[int, float], None] | None = None

    def observe(self, step: int, dt: float) -> str:
        self.count += 1
        if self.count <= self.cfg.warmup:
            self.mean = dt if self.count == 1 else self.mean
            self.mean += self.cfg.alpha * (dt - self.mean)
            self.var += self.cfg.alpha * ((dt - self.mean) ** 2 - self.var)
            return "WARMUP"
        sd = max(self.var, 1e-12) ** 0.5
        z = (dt - self.mean) / sd if sd > 0 else 0.0
        if z > self.cfg.sigma_straggler:
            status = "STRAGGLER"
            self.streak += 1
            self.events.append((step, dt, z))
            if self.streak >= self.cfg.patience and self.on_straggler:
                self.on_straggler(step, dt)
                self.streak = 0
        elif z > self.cfg.sigma_slow:
            status = "SLOW"
            self.streak = 0
        else:
            status = "OK"
            self.streak = 0
            # only update baseline with healthy steps
            self.mean += self.cfg.alpha * (dt - self.mean)
            self.var += self.cfg.alpha * ((dt - self.mean) ** 2 - self.var)
        return status
