"""Straggler detection + mitigation policy.

At 1000+ nodes, slow hosts (thermal throttling, failing HBM, network
degradation) stretch every synchronous collective.  The watchdog keeps an
EWMA/EWVAR of step wall-times, flags steps beyond ``k`` sigma, and drives a
policy:

  observe -> {OK, SLOW, STRAGGLER}
  STRAGGLER streaks >= patience  ->  action callback (checkpoint-and-
  rebalance on real deployments; here: the elastic controller's detect
  hook — see ft/elastic.py — and synthetic-trace tests).

Two hard-won details of the baseline update rule:

* SLOW/STRAGGLER steps never feed the EWMA (a degraded step must not
  drag the healthy baseline up), so a LEGITIMATE regime shift — e.g. the
  schedule switch a straggler action itself performs — would otherwise
  flag every subsequent step forever.  After ``on_straggler`` fires the
  watchdog therefore RE-BASELINES: statistics reset and the warmup
  window re-learns the new regime.
* The EWVAR after a constant-duration warmup is ~0, so the first
  micro-jitter step would z-score to infinity.  The z-score's sigma is
  floored at ``min_rel_sigma`` of the current mean.

A complementary knob it can pull on a live system: switch the grad-sync
schedule (Corollary 2) — e.g. from 'halving' to 'sqrt' — trading more,
smaller rounds for less per-round payload so a slow link hurts each round
less; the launcher re-jits with the new schedule at the next checkpoint
boundary (schedules are trace-time static).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable


@dataclass
class WatchdogConfig:
    alpha: float = 0.1          # EWMA smoothing
    sigma_slow: float = 2.0     # flag threshold
    sigma_straggler: float = 4.0
    patience: int = 3           # straggler streak before action
    warmup: int = 5             # steps ignored (compile etc.)
    min_rel_sigma: float = 0.05  # z-score sigma floor, as a fraction of the
    #                              mean — guards the near-zero-variance
    #                              warmup exit (constant-duration warmups
    #                              would otherwise z-score jitter to inf)


@dataclass
class Watchdog:
    cfg: WatchdogConfig = field(default_factory=WatchdogConfig)
    mean: float = 0.0
    var: float = 0.0
    count: int = 0
    streak: int = 0
    events: list = field(default_factory=list)
    rebaselines: list = field(default_factory=list)
    on_straggler: Callable[[int, float], None] | None = None

    def rebaseline(self, step: int | None = None) -> None:
        """Drop the learned baseline and re-enter warmup.

        Called automatically after ``on_straggler`` fires (the action —
        schedule switch, rank drain, elastic re-plan — changes the step-
        time regime on purpose, so the old EWMA is stale by design);
        also callable by the elastic controller after a resume at a new
        world size.
        """
        self.mean = 0.0
        self.var = 0.0
        self.count = 0
        self.streak = 0
        if step is not None:
            self.rebaselines.append(step)

    def observe(self, step: int, dt: float) -> str:
        self.count += 1
        if self.count <= self.cfg.warmup:
            self.mean = dt if self.count == 1 else self.mean
            self.mean += self.cfg.alpha * (dt - self.mean)
            self.var += self.cfg.alpha * ((dt - self.mean) ** 2 - self.var)
            return "WARMUP"
        sd = max(self.var, 1e-12) ** 0.5
        sd = max(sd, self.cfg.min_rel_sigma * abs(self.mean))
        z = (dt - self.mean) / sd if sd > 0 else 0.0
        if z > self.cfg.sigma_straggler:
            status = "STRAGGLER"
            self.streak += 1
            self.events.append((step, dt, z))
            if self.streak >= self.cfg.patience and self.on_straggler:
                self.on_straggler(step, dt)
                # The action changed the regime on purpose — re-learn it
                # instead of flagging every post-action step forever.
                self.rebaseline(step)
        elif z > self.cfg.sigma_slow:
            status = "SLOW"
            self.streak = 0
        else:
            status = "OK"
            self.streak = 0
            # only update baseline with healthy steps
            self.mean += self.cfg.alpha * (dt - self.mean)
            self.var += self.cfg.alpha * ((dt - self.mean) ** 2 - self.var)
        return status
