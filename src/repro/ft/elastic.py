"""Elastic recovery: detect → drain → re-plan → reshard → resume.

The circulant schedules are round-optimal at ANY p (paper Theorem 1/2 —
no power-of-two padding, no rank-count restriction), which makes an
elastic rank-set change cheap: re-planning after losing or gaining ranks
is just ``plan(spec, p=new_world)`` — a trace-time table rebuild, never
a topology rewrite.  :class:`ElasticController` owns that state machine:

``detect``
    A :class:`~repro.ft.failures.RankFailure` (or a real rank loss)
    surfaces at some step; :meth:`ElasticController.propose_world` maps
    the surviving rank set to the next world size, clamped to
    ``[min_world, max_world]``.
``drain``
    Training stops at the LAST STEP BOUNDARY: the caller-supplied
    ``drain`` hook flushes/performs the final checkpoint for the old
    world (bounded retry/backoff absorbs transient
    :class:`~repro.ft.failures.CheckpointIOError`\\ s).
``re-plan``
    Every active :class:`~repro.core.spec.CollectiveSpec` (see
    :func:`active_specs`) is compiled at the new p and pushed through
    the STATIC verifier (``analysis.verify.assert_verified`` — Theorem 1
    partition, delivery, width invariants; microseconds, no devices)
    BEFORE any data moves on the new world.  Plans cached for the old
    world are then evicted via ``plan.invalidate(p=old_world)``.
``reshard``
    The caller-supplied ``reshard`` hook restores the drained checkpoint
    at the new world — full flat optimizer vectors slice to any p
    (``checkpoint.reshard_flat``), and
    ``optim.zero1.resize_zero1_state`` remaps m/v/EF shards (EF mass
    conservation — see its docstring).  Same retry/backoff budget.
``resume``
    The controller adopts the new world; the caller rebuilds its step
    function and continues.

Everything is driven through injected ``clock``/``sleep`` so the
deadline and backoff machinery is unit-testable without real waiting.
If the recovery deadline passes (or the IO retry budget is exhausted
past it), the controller falls back to a CLEAN RESTART via the caller's
``restart`` hook — the classic kill-and-relaunch drill — rather than
wedging; with no restart hook it raises :class:`ElasticAbort`.

World-size discipline: inside ``repro.ft`` the ONLY source of truth for
the live world is ``ElasticController.world`` — nothing here reads
device counts from the runtime (enforced by the repo lint rule
``ft-world-via-controller``), because during a resize the runtime's
device count and the logical world disagree by construction.
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

from .failures import CheckpointIOError  # noqa: F401  (re-export convenience)

#: recovery state machine order (report ``phases`` entries follow it).
PHASES = ("drain", "replan", "reshard", "resume")


class ElasticAbort(RuntimeError):
    """Elastic recovery could not complete (deadline passed, IO retry
    budget exhausted past the deadline, or the proposed world is outside
    the configured bounds) and no clean-restart fallback was given."""


@dataclass(frozen=True)
class ElasticConfig:
    """Policy knobs of the recovery state machine.

    ``io_retries`` transient-IO retries (per drain/reshard phase) with
    exponential backoff starting at ``io_backoff_s``;
    ``recovery_deadline_s`` bounds the WHOLE recovery — past it the
    controller falls back to clean restart instead of retrying further.
    """

    min_world: int = 1
    max_world: int | None = None
    io_retries: int = 3
    io_backoff_s: float = 0.05
    recovery_deadline_s: float = 60.0
    verify_plans: bool = True
    axis_name: str = "data"

    def __post_init__(self):
        if self.min_world < 1:
            raise ValueError(f"min_world must be >= 1, got {self.min_world}")
        if self.max_world is not None and self.max_world < self.min_world:
            raise ValueError(
                f"max_world {self.max_world} < min_world {self.min_world}")
        if self.io_retries < 0 or self.io_backoff_s < 0 \
                or self.recovery_deadline_s <= 0:
            raise ValueError("io_retries/io_backoff_s must be >= 0 and "
                             "recovery_deadline_s > 0")


@dataclass(frozen=True)
class ReplanRecord:
    """One spec re-planned at the new world: compile+verify latency in
    microseconds (the quantity the elastic CI gate budgets)."""

    spec: Any
    old_p: int
    new_p: int
    plan_us: float
    verified: bool


@dataclass
class RecoveryReport:
    """What one :meth:`ElasticController.recover` run did.

    ``phases`` lists ``(name, seconds)`` in :data:`PHASES` order;
    ``io_failures`` counts transient IO errors absorbed by retry;
    ``evicted`` is how many old-world plans left the plan cache;
    ``restarted`` flags the clean-restart fallback path.
    """

    trigger_step: int
    old_world: int
    new_world: int
    phases: list = field(default_factory=list)
    replans: tuple = ()
    evicted: int = 0
    io_failures: int = 0
    restarted: bool = False
    drained: Any = None

    @property
    def replan_us(self) -> float:
        """Total re-plan + verify latency (µs) across all specs."""
        return sum(r.plan_us for r in self.replans)

    @property
    def total_s(self) -> float:
        return sum(s for _, s in self.phases)


def active_specs(sync, model_cfg=None, ep_world: int | None = None):
    """The data-axis :class:`CollectiveSpec`\\ s a resize must re-plan.

    Thin funnel over :func:`repro.train.steps.collective_specs` keeping
    only the ``data``-role specs: a data-world resize changes p on the
    data axes, while the MoE ``ep`` axis is a model-parallel axis whose
    size is untouched by it (its plans stay cached and valid).
    """
    from repro.train.steps import collective_specs
    return tuple(sp for role, sp in
                 collective_specs(sync, model_cfg, ep_world)
                 if role == "data")


class ElasticController:
    """Drives detect → drain → re-plan → reshard → resume on rank-set
    changes.

    The controller is runtime-agnostic: the caller supplies ``drain``
    (flush/write the boundary checkpoint; returns e.g. the drained
    step), ``reshard`` (restore + remap state at the new world; returns
    the resumed payload) and optionally ``restart`` (clean-restart
    fallback).  ``clock``/``sleep`` are injectable for tests.
    """

    def __init__(self, world: int, cfg: ElasticConfig | None = None, *,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        if world < 1:
            raise ValueError(f"world must be >= 1, got {world}")
        self.cfg = cfg or ElasticConfig()
        self._world = world
        self._clock = clock
        self._sleep = sleep
        self.reports: list[RecoveryReport] = []

    @property
    def world(self) -> int:
        """The live logical world size — THE rank-world read inside
        ``repro.ft`` (see the module docstring on why not the runtime's
        device count)."""
        return self._world

    # -- detect --------------------------------------------------------------

    def propose_world(self, lost_ranks: Sequence[int] = ()) -> int:
        """World size after losing ``lost_ranks`` (deduplicated), clamped
        to ``max_world``; raises :class:`ElasticAbort` below
        ``min_world`` — with fewer survivors than that, recovery is not
        allowed to proceed at all."""
        new = self._world - len(set(lost_ranks))
        if self.cfg.max_world is not None:
            new = min(new, self.cfg.max_world)
        if new < self.cfg.min_world:
            raise ElasticAbort(
                f"{len(set(lost_ranks))} rank(s) lost from world "
                f"{self._world}: {new} survivors < min_world "
                f"{self.cfg.min_world}")
        return new

    # -- internal machinery --------------------------------------------------

    @contextlib.contextmanager
    def _phase(self, report: RecoveryReport, name: str):
        t0 = self._clock()
        try:
            yield
        finally:
            report.phases.append((name, self._clock() - t0))

    def _check_deadline(self, deadline: float) -> None:
        if self._clock() > deadline:
            raise ElasticAbort(
                f"recovery deadline ({self.cfg.recovery_deadline_s}s) "
                f"exceeded")

    def _retry_io(self, fn: Callable[[], Any], deadline: float,
                  report: RecoveryReport, what: str) -> Any:
        """Run ``fn`` riding out transient checkpoint-IO failures:
        ``io_retries`` retries with exponential backoff, all under the
        recovery deadline.  Retries cover :class:`CheckpointIOError` /
        ``OSError`` and :class:`~repro.checkpoint.CheckpointError` (a
        failed background save surfaces as the latter)."""
        from repro.checkpoint import CheckpointError
        last: BaseException | None = None
        for attempt in range(self.cfg.io_retries + 1):
            self._check_deadline(deadline)
            try:
                return fn()
            except (OSError, CheckpointError) as e:
                report.io_failures += 1
                last = e
                if attempt < self.cfg.io_retries:
                    self._sleep(self.cfg.io_backoff_s * (2 ** attempt))
        raise ElasticAbort(
            f"{what} still failing after {self.cfg.io_retries + 1} "
            f"attempts: {last!r}") from last

    # -- re-plan -------------------------------------------------------------

    def replan(self, specs: Sequence[Any], new_world: int,
               report: RecoveryReport | None = None
               ) -> tuple[ReplanRecord, ...]:
        """Compile every spec at ``new_world`` and statically verify it
        BEFORE the new world moves any data; then evict the old world's
        plans from the cache.  Returns the per-spec records (also stored
        on ``report``)."""
        from repro.analysis.verify import assert_verified
        from repro.core.plan import plan
        recs = []
        for spec in specs:
            t0 = self._clock()
            pl = plan(spec, p=new_world, axis_name=self.cfg.axis_name)
            if self.cfg.verify_plans:
                assert_verified(pl)
            recs.append(ReplanRecord(
                spec=spec, old_p=self._world, new_p=new_world,
                plan_us=(self._clock() - t0) * 1e6,
                verified=self.cfg.verify_plans))
        evicted = 0
        if new_world != self._world:
            # A no-op "resize" must not evict the plans just compiled.
            evicted = plan.invalidate(p=self._world,
                                      axis_name=self.cfg.axis_name)
        if report is not None:
            report.replans = tuple(recs)
            report.evicted = evicted
        return tuple(recs)

    # -- the full state machine ---------------------------------------------

    def recover(self, step: int, new_world: int, specs: Sequence[Any], *,
                drain: Callable[[int], Any],
                reshard: Callable[[int], Any],
                restart: Callable[[], Any] | None = None
                ) -> tuple[RecoveryReport, Any]:
        """Run drain → re-plan → reshard → resume; returns
        ``(report, payload)`` where ``payload`` is ``reshard``'s return
        value (or ``restart``'s on the fallback path).

        ``step`` is the boundary the run drained at (the failure was
        detected during/after it).  World bounds are enforced up front
        and never fall back — a world outside ``[min_world, max_world]``
        is a caller error, not a recoverable fault.
        """
        if new_world < self.cfg.min_world or (
                self.cfg.max_world is not None
                and new_world > self.cfg.max_world):
            raise ElasticAbort(
                f"proposed world {new_world} outside "
                f"[{self.cfg.min_world}, {self.cfg.max_world}]")
        report = RecoveryReport(trigger_step=step, old_world=self._world,
                                new_world=new_world)
        deadline = self._clock() + self.cfg.recovery_deadline_s
        try:
            with self._phase(report, "drain"):
                report.drained = self._retry_io(
                    lambda: drain(step), deadline, report, "drain")
            with self._phase(report, "replan"):
                self._check_deadline(deadline)
                self.replan(specs, new_world, report)
            with self._phase(report, "reshard"):
                payload = self._retry_io(
                    lambda: reshard(new_world), deadline, report, "reshard")
            with self._phase(report, "resume"):
                self._world = new_world
        except ElasticAbort:
            if restart is None:
                self.reports.append(report)
                raise
            # Hard fallback: abandon in-flight recovery, clean restart.
            with self._phase(report, "resume"):
                report.restarted = True
                payload = restart()
                self._world = new_world
        self.reports.append(report)
        return report, payload
