from .watchdog import Watchdog, WatchdogConfig  # noqa: F401
from .failures import FailureInjector, SimulatedFailure  # noqa: F401
