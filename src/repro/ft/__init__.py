from .watchdog import Watchdog, WatchdogConfig  # noqa: F401
from .failures import (CheckpointIOError, FailureInjector,  # noqa: F401
                       FailurePlan, FaultEvent, RankFailure, SimulatedFailure)
from .elastic import (PHASES, ElasticAbort, ElasticConfig,  # noqa: F401
                      ElasticController, RecoveryReport, ReplanRecord,
                      active_specs)
