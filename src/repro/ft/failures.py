"""Rank-level failure injection for elastic recovery drills.

The seed of this module was a single step-triggered exception
(:class:`FailureInjector`, kept for the classic restart drill).  The
elastic runtime needs rank-LEVEL faults on configurable schedules, so a
:class:`FailurePlan` holds a sequence of :class:`FaultEvent`\\ s:

``rank_loss``
    Rank ``rank`` dies at ``step``: :meth:`FailurePlan.check` raises
    :class:`RankFailure` (once — a dead rank stays dead).  The drill
    harness does NOT catch-and-ignore it; the elastic controller runs
    the drain → re-plan → reshard → resume machine (ft/elastic.py).
``slow_link``
    A degraded link adds ``delay_s`` seconds to every step in
    ``[step, step + duration)``: :meth:`FailurePlan.slow_delay` is added
    to the wall time the watchdog observes, so the straggler policy —
    not an exception — is what detects it.
``ckpt_io``
    Transient checkpoint-IO failure: starting at ``step``, the next
    ``duration`` checkpoint I/O operations raise
    :class:`CheckpointIOError` (:meth:`FailurePlan.io_hook` plugs into
    ``CheckpointManager(io_hook=...)``).  Transient by construction —
    the elastic controller's bounded retry/backoff must ride it out.

Exceptions deliberately mirror real failure surfaces: a real SIGKILL is
not catchable either, so the training loop never handles
:class:`RankFailure` itself — only the recovery harness does.
"""
from __future__ import annotations

from dataclasses import dataclass, field


class SimulatedFailure(RuntimeError):
    """Injected whole-process failure (the classic restart drill)."""


class RankFailure(SimulatedFailure):
    """A specific rank died; carries ``rank`` and ``step`` for the
    controller's world-size proposal."""

    def __init__(self, rank: int, step: int):
        super().__init__(f"injected loss of rank {rank} at step {step}")
        self.rank = rank
        self.step = step


class CheckpointIOError(OSError):
    """Transient checkpoint-IO failure (injected or real); the elastic
    controller retries these with bounded backoff."""


_KINDS = ("rank_loss", "slow_link", "ckpt_io")


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``rank`` applies to ``rank_loss``;
    ``delay_s``/``duration`` to ``slow_link``; ``duration`` (number of
    consecutive failing IO ops) to ``ckpt_io``."""

    step: int
    kind: str = "rank_loss"
    rank: int = 0
    delay_s: float = 0.0
    duration: int = 1

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; one of {_KINDS}")
        if self.step < 0 or self.duration < 1 or self.delay_s < 0:
            raise ValueError(f"bad fault event {self}")


@dataclass
class FailurePlan:
    """A schedule of :class:`FaultEvent`\\ s driving one drill run.

    Mutable on purpose: fired one-shot events are recorded in ``fired``
    so a recovery that rewinds the step counter does not re-kill the
    same rank, and the transient-IO countdown lives here.
    """

    events: tuple[FaultEvent, ...] = ()
    fired: list = field(default_factory=list)
    _io_remaining: int | None = field(default=None, repr=False)

    def __post_init__(self):
        self.events = tuple(self.events)

    # -- rank loss ----------------------------------------------------------

    def check(self, step: int) -> None:
        """Raise :class:`RankFailure` for a ``rank_loss`` scheduled at
        ``step`` that has not fired yet."""
        for ev in self.events:
            if ev.kind == "rank_loss" and ev.step == step \
                    and ev not in self.fired:
                self.fired.append(ev)
                raise RankFailure(ev.rank, step)

    # -- slow link ----------------------------------------------------------

    def slow_delay(self, step: int) -> float:
        """Extra seconds of step time injected at ``step`` (sum of all
        active ``slow_link`` windows) — add to the duration the watchdog
        observes."""
        return sum(ev.delay_s for ev in self.events
                   if ev.kind == "slow_link"
                   and ev.step <= step < ev.step + ev.duration)

    # -- checkpoint IO ------------------------------------------------------

    def io_hook(self, step: int) -> None:
        """``CheckpointManager(io_hook=...)`` entry point: raise
        :class:`CheckpointIOError` for the next ``duration`` IO
        operations once a ``ckpt_io`` event's step has been reached."""
        for ev in self.events:
            if ev.kind == "ckpt_io" and step >= ev.step \
                    and ev not in self.fired:
                self.fired.append(ev)
                self._io_remaining = (self._io_remaining or 0) + ev.duration
        if self._io_remaining:
            self._io_remaining -= 1
            raise CheckpointIOError(
                f"injected transient checkpoint-IO failure at step {step} "
                f"({self._io_remaining} more to come)")


@dataclass
class FailureInjector:
    """Legacy single-event injector: raises :class:`SimulatedFailure` at
    ``fail_at_step`` — the whole-process crash of the restart drill."""

    fail_at_step: int | None = None

    def check(self, step: int):
        if self.fail_at_step is not None and step == self.fail_at_step:
            raise SimulatedFailure(f"injected failure at step {step}")
