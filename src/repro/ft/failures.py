"""Failure injection for restart drills.

``FailureInjector`` raises ``SimulatedFailure`` at a configured step —
the training loop does NOT catch it (a real SIGKILL wouldn't be catchable
either); the restart drill re-invokes the trainer, which resumes from the
last completed checkpoint and must reproduce the uninterrupted loss
trajectory exactly (tested in tests/test_ft.py).
"""
from __future__ import annotations

from dataclasses import dataclass


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    fail_at_step: int | None = None

    def check(self, step: int):
        if self.fail_at_step is not None and step == self.fail_at_step:
            raise SimulatedFailure(f"injected failure at step {step}")
